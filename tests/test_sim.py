"""End-to-end simulator tests + invariants."""
import math

import numpy as np
import pytest

from repro.core.controller import ControllerConfig, SageServeController
from repro.core.queue_manager import QueueManager
from repro.core.scaling import make_policy
from repro.sim.perfmodel import PROFILES, sustained_input_tps
from repro.sim.simulator import SimConfig, Simulation
from repro.sim.workload import (PAPER_MODELS, REGIONS, WorkloadSpec,
                                generate, tps_series)


@pytest.fixture(scope="module")
def small_trace():
    return generate(WorkloadSpec(days=0.15, scale=0.03, seed=1))


def test_workload_statistics():
    # niw volume set to the Jul-2025 global mix (§3: IW = 72%, ~3:1);
    # the default 0.2e6 anchor is the Nov-2024 West-US peak day (7:1)
    reqs = generate(WorkloadSpec(days=1.0, scale=0.02, seed=0,
                                 niw_per_region_day=0.54e6))
    tiers = {t: sum(1 for r in reqs if r.tier == t)
             for t in ("IW-F", "IW-N", "NIW")}
    iw = tiers["IW-F"] + tiers["IW-N"]
    assert tiers["IW-F"] > tiers["IW-N"] > 0          # IW-F largest tier
    assert 0.6 < iw / len(reqs) < 0.85                 # ~72% IW
    assert 2.0 < iw / tiers["NIW"] < 5.0               # ~3:1 IW:NIW
    prompts = np.array([r.prompt_tokens for r in reqs])
    assert np.median(prompts) > 1000                   # Fig 10: most > 1k
    outs = np.array([r.output_tokens for r in reqs])
    assert np.median(outs) < 1000
    # diurnal: mid-day rate >> night rate for IW
    arr = np.array([r.arrival for r in reqs if r.tier == "IW-F"])
    hist, _ = np.histogram(arr, bins=24, range=(0, 86400))
    assert hist.max() > 3 * max(hist.min(), 1)
    s = tps_series(reqs)
    assert ("llama2-70b", "eastus") in s


def test_sim_completes_and_invariants(small_trace):
    cfg = SimConfig(policy=make_policy("reactive"),
                    queue_manager=QueueManager(),
                    initial_instances=3, spot_spare=8,
                    drain_grace=3 * 3600.0)
    rep = Simulation(small_trace, cfg, name="t").run()
    done = [r for r in small_trace if not math.isnan(r.e2e)]
    assert len(done) / len(small_trace) > 0.97
    for r in done:
        assert r.ttft >= 0 and r.e2e >= r.ttft          # causality
        assert r.admitted >= r.arrival
        assert r.served_region in REGIONS
    assert rep.total_instance_hours() > 0
    # min instance floor respected in the utilization trace
    for key, tr in rep.util_trace.items():
        assert min(c for (_, _, c) in tr) >= 2


def test_siloed_vs_unified_instance_hours(small_trace):
    runs = {}
    for siloed in (True, False):
        cfg = SimConfig(policy=make_policy("reactive"),
                        queue_manager=None if siloed else QueueManager(),
                        siloed=siloed, siloed_iw=3, siloed_niw=2,
                        initial_instances=3, spot_spare=8,
                        drain_grace=3 * 3600.0)
        runs[siloed] = Simulation(small_trace, cfg,
                                  name=f"silo={siloed}").run()
    # unified consolidates: fewer or equal instance-hours
    assert (runs[False].total_instance_hours()
            <= runs[True].total_instance_hours() * 1.02)


def test_lt_ua_with_controller_runs(small_trace):
    theta = {m: 0.7 * sustained_input_tps(PROFILES[m])
             for m in PAPER_MODELS}
    ctl = SageServeController(ControllerConfig(
        models=list(PAPER_MODELS), regions=list(REGIONS), theta=theta,
        min_instances=2, fit_steps=60))
    cfg = SimConfig(policy=make_policy("lt-ua"), controller=ctl,
                    queue_manager=QueueManager(),
                    initial_instances=3, spot_spare=8,
                    drain_grace=3 * 3600.0)
    rep = Simulation(small_trace, cfg, name="lt-ua").run()
    done = sum(1 for r in small_trace if not math.isnan(r.e2e))
    assert done / len(small_trace) > 0.97
    assert ctl.solve_history, "hourly ILP ran"


def test_burst_spec():
    spec = WorkloadSpec(days=0.2, scale=0.02, seed=3, burst_mult=8.0,
                        burst_hours=(2.0,))
    reqs = generate(spec)
    arr = np.array([r.arrival for r in reqs if r.tier == "IW-F"])
    in_burst = ((arr >= 7200) & (arr < 10800)).sum()
    before = ((arr >= 3600) & (arr < 7200)).sum()
    assert in_burst > 3 * before
