"""Placement study: the third control knob under stress scenarios.

Compares the placement-aware plan (ILP y binaries + staged lead-time
actuation) against the placement-blind co-optimized plan (PR 3's
``lt-ua+plan``) on the scenarios placement exists for:

- ``outage``      — a region goes dark for three hours; the forecast-
                    aware planner evacuates at the outage start (not a
                    planning period early) and redeploys afterwards;
- ``popshift``    — hour-indexed model-popularity shift: one model's
                    demand vanishes in one region and doubles in
                    another, so static all-models-everywhere placement
                    pays idle min-instance floors forever;
- ``combined``    — both at once (the default study).

Because each scenario shapes *both* the workload (popularity shifts)
and the stack (outage windows), the sweep uses the experiment layer's
explicit-variant form: one ``Variant`` per (scenario, blind|aware),
grouped by scenario via ``workload_name`` so ``deltas(baseline="blind")``
pairs each aware run with its blind twin on the identical trace.

Reported: total ``gpu_dollars`` per strategy (the paper's §7.2.1
accounting), the dollar delta, and per-tier IW SLA-violation fractions
— the acceptance gate is "placement saves dollars without giving up IW
SLA attainment".
"""
from __future__ import annotations

from benchmarks.common import csv_line
from repro.api import OutageWindow, PolicySpec, ScenarioSpec, StackSpec
from repro.api.experiment import (ExperimentSpec, Variant, run_experiment)
from repro.sim.workload import (PAPER_MODELS, REGIONS, PopularityShift,
                                WorkloadSpec)

SCENARIOS = ("outage", "popshift", "combined")


def scenario_inputs(name: str, days: float, scale: float, seed: int = 7):
    """WorkloadSpec + ScenarioSpec for one named scenario."""
    shifts = ()
    outages = ()
    if name in ("popshift", "combined"):
        # bloom's demand leaves westus and doubles in eastus from hour 4
        shifts = (
            PopularityShift("bloom-176b", 4.0, 24.0 * days, 0.0,
                            regions=("westus",)),
            PopularityShift("bloom-176b", 4.0, 24.0 * days, 2.0,
                            regions=("eastus",)),
        )
    if name in ("outage", "combined"):
        outages = (OutageWindow("centralus", 6 * 3600.0, 9 * 3600.0),)
    workload = WorkloadSpec(days=days, scale=scale, seed=seed,
                            pop_shifts=shifts)
    return workload, ScenarioSpec(outages=outages)


def _stack(scen: ScenarioSpec, aware: bool, fit_steps: int = 40,
           initial_instances: int = 3, spot_spare: int = 8) -> StackSpec:
    kw = {"fit_steps": fit_steps, "use_routing": True}
    if aware:
        kw["use_placement"] = True
    return StackSpec(
        models=PAPER_MODELS, regions=REGIONS, scaler="lt-ua",
        planner=PolicySpec("sageserve", kw), router="plan",
        initial_instances=initial_instances, spot_spare=spot_spare,
        drain_grace=2 * 3600.0, scenario=scen)


def placement_experiment(scenarios, days: float, scale: float,
                         seed: int = 7) -> ExperimentSpec:
    """Explicit-variant sweep: (scenario × {blind, aware}), each pair
    sharing one workload so the comparison runs on the identical trace."""
    variants = []
    for scen_name in scenarios:
        workload, scen = scenario_inputs(scen_name, days, scale, seed)
        for aware in (False, True):
            label = "aware" if aware else "blind"
            variants.append(Variant(
                name=f"{scen_name}/{label}", stack=_stack(scen, aware),
                workload=workload, strategy=label,
                workload_name=scen_name))
    return ExperimentSpec(name="fig_placement", variants=tuple(variants))


def run(quick: bool = False, scenarios=SCENARIOS, jobs=None) -> None:
    days, scale = (0.3, 0.015) if quick else (0.5, 0.03)
    results = run_experiment(placement_experiment(scenarios, days, scale),
                             jobs=jobs)
    deltas = results.deltas(baseline="blind")
    for scen_name in scenarios:
        blind = results.get(f"{scen_name}/blind")
        place = results.get(f"{scen_name}/aware")
        csv_line(f"fig_placement.{scen_name}.requests", place.n_requests,
                 f"{place.completion:.3f} completed (aware)")
        csv_line(f"fig_placement.{scen_name}.gpu_dollars.blind",
                 round(blind.total_gpu_dollars, 2))
        csv_line(f"fig_placement.{scen_name}.gpu_dollars.aware",
                 round(place.total_gpu_dollars, 2))
        sav = deltas[f"{scen_name}/aware"]["gpu_dollars"]
        csv_line(f"fig_placement.{scen_name}.savings_dollars",
                 round(sav["delta"], 2), f"{sav['pct']:.1f}%")
        for tier in ("IW-F", "IW-N"):
            csv_line(
                f"fig_placement.{scen_name}.sla_viol.{tier}",
                round(place.sla_violations.get(tier, 0.0), 4),
                f"blind {blind.sla_violations.get(tier, 0.0):.4f}")
    print("# fig_placement complete", flush=True)


def smoke(jobs=None) -> int:
    """Tiny outage + popularity-shift run for CI (scripts/check.sh):
    placement-aware must at least match the blind plan on dollars and
    stay near its IW SLA attainment."""
    import sys
    results = run_experiment(
        placement_experiment(("combined",), days=0.3, scale=0.015),
        jobs=jobs)
    blind = results.get("combined/blind")
    place = results.get("combined/aware")
    frac = place.completion
    csv_line("placement_smoke.completion", round(frac, 4))
    csv_line("placement_smoke.gpu_dollars.blind",
             round(blind.total_gpu_dollars, 2))
    csv_line("placement_smoke.gpu_dollars.aware",
             round(place.total_gpu_dollars, 2))
    if frac < 0.97:
        print(f"FAILED placement smoke: completion {frac:.1%}",
              file=sys.stderr)
        return 1
    if place.total_gpu_dollars > blind.total_gpu_dollars:
        print("FAILED placement smoke: placement-aware spent more than "
              "placement-blind", file=sys.stderr)
        return 1
    for tier in ("IW-F", "IW-N"):
        b = blind.sla_violations.get(tier, 0.0)
        p = place.sla_violations.get(tier, 0.0)
        if p > b + 0.02:
            print(f"FAILED placement smoke: {tier} SLA violations "
                  f"{p:.3f} exceed blind {b:.3f} + 2pp", file=sys.stderr)
            return 1
    print("# placement smoke ok", flush=True)
    return 0


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    run(quick="--quick" in sys.argv)
