"""Metrics collection & reporting: TTFT/E2E percentiles, SLA violations,
instance-hours, wasted scaling hours, spot donations, memory-util traces."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.types import Request, TIER_IWF, TIER_IWN, TIER_NIW, TTFT_SLA

Key = Tuple[str, str]


def _pct(vals: Sequence[float], q: float) -> float:
    vals = np.asarray(vals, np.float64)
    vals = vals[~np.isnan(vals)]
    return float(np.percentile(vals, q)) if vals.size else math.nan


@dataclasses.dataclass
class Report:
    name: str
    ttft: Dict[str, Dict[str, float]]          # tier -> {p50,p75,p95,mean}
    e2e: Dict[str, Dict[str, float]]
    sla_violations: Dict[str, float]           # tier -> fraction
    completed: Dict[str, int]
    dropped: Dict[str, int]
    instance_hours: Dict[Key, float]
    wasted_hours: Dict[Key, float]
    spot_hours: Dict[str, float]
    scale_out_events: int
    scale_in_events: int
    util_trace: Dict[Key, List[Tuple[float, float, int]]]  # t, util, count
    retry_dropped: int = 0       # dropped after exhausting routing retries
    parked: int = 0              # still parked in the queue manager at end
    # dollar accounting: instance-hours priced by the stack's CostModel
    # (paper §7.2.1, α = $98.32/h by default)
    gpu_dollars: Dict[Key, float] = dataclasses.field(default_factory=dict)
    wasted_dollars: Dict[Key, float] = dataclasses.field(
        default_factory=dict)

    # ------------------------------------------------------------ summaries
    def total_instance_hours(self) -> float:
        return sum(self.instance_hours.values())

    def total_wasted_hours(self) -> float:
        return sum(self.wasted_hours.values())

    def total_spot_hours(self) -> float:
        return sum(self.spot_hours.values())

    def total_gpu_dollars(self) -> float:
        return sum(self.gpu_dollars.values())

    def total_wasted_dollars(self) -> float:
        return sum(self.wasted_dollars.values())

    def savings_vs(self, baseline: "Report") -> Dict[str, float]:
        """Dollar savings relative to a baseline run of the same trace
        (the paper's headline: LT-UA vs the reactive deployment)."""
        base = baseline.total_gpu_dollars()
        mine = self.total_gpu_dollars()
        return {"dollars": base - mine,
                "pct": 100.0 * (1.0 - mine / base) if base else 0.0}

    def summary(self) -> str:
        lines = [f"== {self.name} =="]
        for tier in (TIER_IWF, TIER_IWN, TIER_NIW):
            if tier not in self.ttft:
                continue
            t, e = self.ttft[tier], self.e2e[tier]
            lines.append(
                f"  {tier:5s} n={self.completed.get(tier, 0):7d} "
                f"TTFT p50={t['p50']:.2f}s p95={t['p95']:.2f}s | "
                f"E2E p95={e['p95']:.1f}s | "
                f"SLA viol={self.sla_violations.get(tier, 0)*100:.1f}%")
        lines.append(
            f"  instance-hours={self.total_instance_hours():.1f} "
            f"wasted={self.total_wasted_hours():.1f} "
            f"spot-donated={self.total_spot_hours():.1f} "
            f"scale-out={self.scale_out_events} in={self.scale_in_events}")
        if self.gpu_dollars:
            lines.append(
                f"  gpu-dollars=${self.total_gpu_dollars():,.0f} "
                f"wasted=${self.total_wasted_dollars():,.0f}")
        if self.retry_dropped or self.parked:
            lines.append(f"  retry-dropped={self.retry_dropped} "
                         f"parked={self.parked}")
        return "\n".join(lines)


def report_to_dict(rep: Report, include_util_trace: bool = True) -> Dict:
    """JSON-able view of a Report: tuple keys flattened to "model|region",
    NaNs to None.  Used by the perf benchmark and the golden-equivalence
    tests."""
    def clean(x):
        return None if (isinstance(x, float) and math.isnan(x)) else x

    d = {
        "name": rep.name,
        "ttft": {t: {k: clean(v) for k, v in d2.items()}
                 for t, d2 in rep.ttft.items()},
        "e2e": {t: {k: clean(v) for k, v in d2.items()}
                for t, d2 in rep.e2e.items()},
        "sla_violations": dict(rep.sla_violations),
        "completed": dict(rep.completed),
        "dropped": dict(rep.dropped),
        "instance_hours": {f"{m}|{r}": v
                           for (m, r), v in rep.instance_hours.items()},
        "wasted_hours": {f"{m}|{r}": v
                         for (m, r), v in rep.wasted_hours.items()},
        "spot_hours": dict(rep.spot_hours),
        "scale_out_events": rep.scale_out_events,
        "scale_in_events": rep.scale_in_events,
        "retry_dropped": rep.retry_dropped,
        "parked": rep.parked,
        "gpu_dollars": {f"{m}|{r}": v
                        for (m, r), v in rep.gpu_dollars.items()},
        "wasted_dollars": {f"{m}|{r}": v
                           for (m, r), v in rep.wasted_dollars.items()},
        "gpu_dollars_total": rep.total_gpu_dollars(),
        "wasted_dollars_total": rep.total_wasted_dollars(),
    }
    if include_util_trace:
        d["util_trace"] = {f"{m}|{r}": [[t, u, c] for (t, u, c) in tr]
                           for (m, r), tr in rep.util_trace.items()}
    return d


def build_report(name: str, requests: Sequence[Request], cluster,
                 util_trace: Dict[Key, List[Tuple[float, float, int]]],
                 retry_dropped: int = 0, parked: int = 0,
                 slo_ttft: Optional[Dict[str, float]] = None) -> Report:
    slo = TTFT_SLA if slo_ttft is None else slo_ttft
    ttft, e2e, viol, comp, drop = {}, {}, {}, {}, {}
    # one columnar pass over the trace (at 10M requests the old per-tier
    # object comprehensions dominated post-run wall-clock)
    groups: Dict[str, List[Request]] = {}
    for r in requests:
        groups.setdefault(r.tier, []).append(r)
    for tier in (TIER_IWF, TIER_IWN, TIER_NIW):
        rs = groups.get(tier)
        if not rs:
            continue
        n = len(rs)
        tt_all = np.fromiter((r.ttft for r in rs), np.float64, n)
        ee_all = np.fromiter((r.e2e for r in rs), np.float64, n)
        done = ~np.isnan(ee_all)
        n_done = int(done.sum())
        comp[tier] = n_done
        drop[tier] = n - n_done
        tt = tt_all[done]
        ee = ee_all[done]
        ttft[tier] = {"p50": _pct(tt, 50), "p75": _pct(tt, 75),
                      "p95": _pct(tt, 95),
                      "mean": float(np.mean(tt)) if n_done else math.nan}
        e2e[tier] = {"p50": _pct(ee, 50), "p75": _pct(ee, 75),
                     "p95": _pct(ee, 95),
                     "mean": float(np.mean(ee)) if n_done else math.nan}
        if tier in slo:
            bad = int((np.isnan(tt_all) | (tt_all > slo[tier])).sum())
        else:
            arr = np.fromiter((r.arrival for r in rs), np.float64, n)
            dl = np.fromiter((r.deadline for r in rs), np.float64, n)
            ok = done & (arr + ee_all <= dl)
            bad = n - int(ok.sum())
        viol[tier] = bad / n
    return Report(
        name=name, ttft=ttft, e2e=e2e, sla_violations=viol,
        completed=comp, dropped=drop,
        instance_hours=cluster.instance_hours(),
        wasted_hours=cluster.wasted_hours(),
        spot_hours=cluster.spot_hours(),
        scale_out_events=cluster.scale_out_events,
        scale_in_events=cluster.scale_in_events,
        util_trace=util_trace,
        retry_dropped=retry_dropped, parked=parked,
        gpu_dollars=cluster.gpu_dollars(),
        wasted_dollars=cluster.wasted_dollars())
