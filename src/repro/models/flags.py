"""Global tracing flags.

SCAN_UNROLL: when True, every lax.scan in the model (layer stacks,
blockwise-attention q-blocks, SSD chunk scan) fully unrolls.  Used by the
dry-run's cost probes: XLA's HloCostAnalysis counts a while-loop body
once regardless of trip count, so roofline FLOPs/bytes are measured on
small unrolled variants and extrapolated linearly in depth
(see launch/dryrun.py).
"""
from __future__ import annotations

import contextlib

SCAN_UNROLL = False
PROBE_BLOCK_Q = None  # override blockwise-attention q-block size in probes

# ---- beyond-paper perf optimizations (EXPERIMENTS.md §Perf) ---------------
# Baseline (paper-faithful jnp implementation) keeps these False.
ATTN_BF16_STREAM = False   # keep QK^T/AV operands in bf16 with fp32
                           # accumulation (preferred_element_type) instead
                           # of materializing fp32 copies of K/V
SEQ_PARALLEL_ATTN = False  # shard attention q-blocks over the model axis
                           # (context parallelism) for archs whose head
                           # counts don't divide the TP degree
MOE_DECODE_DISPATCH = False  # decode MoE via capacity dispatch (all-to-all)
                             # when T*topk >= num_experts, instead of
                             # gathering expert weights per token
WHERE_CACHE_UPDATE = False   # decode cache insertion via elementwise
                             # where() instead of scatter: GSPMD partitions
                             # it without the involuntary full
                             # rematerialization scatters trigger on a
                             # seq-sharded cache


def scan_unroll():
    return True if SCAN_UNROLL else 1


@contextlib.contextmanager
def unrolled_scans():
    global SCAN_UNROLL
    old = SCAN_UNROLL
    SCAN_UNROLL = True
    try:
        yield
    finally:
        SCAN_UNROLL = old
