"""Scaling policies + routing logic."""
import pytest

from repro.core.routing import (ThresholdRouter, pick_endpoint,
                                route_global, route_jsq)
from repro.core.scaling import EndpointView, LTPolicy, ReactivePolicy


def view(util, inst=4, pending=0, tps=0.0, model="m", region="r"):
    return EndpointView(model, region, util, inst, pending, tps)


def test_reactive_thresholds_and_cooldown():
    p = ReactivePolicy(up=0.7, down=0.3, cooldown=15.0, min_instances=2)
    assert p.on_request(view(0.75), now=0.0)[0].delta == 1
    assert p.on_request(view(0.95), now=5.0) == []       # cooldown
    assert p.on_request(view(0.2), now=20.0)[0].delta == -1
    assert p.on_request(view(0.2, inst=2), now=40.0) == []  # min floor
    assert p.on_request(view(0.5), now=60.0) == []        # dead band


def test_lt_i_jumps_to_target():
    p = LTPolicy(mode="I")
    p.set_targets({("m", "r"): 7}, {("m", "r"): 1000.0}, now=0.0)
    acts = p.on_tick([view(0.5, inst=4)], now=10.0)
    assert acts[0].delta == 3
    acts = p.on_tick([view(0.5, inst=9)], now=20.0)
    assert acts[0].delta == -2


def test_lt_u_defers_on_util():
    p = LTPolicy(mode="U")
    p.set_targets({("m", "r"): 7}, {("m", "r"): 1000.0}, now=0.0)
    assert p.on_tick([view(0.5, inst=4)], now=10.0) == []     # no breach
    assert p.on_tick([view(0.8, inst=4)], now=20.0)[0].delta == 1
    assert p.on_tick([view(0.8, inst=7)], now=40.0) == []     # at target
    assert p.on_tick([view(0.2, inst=9)], now=60.0)[0].delta == -1


def test_lt_ua_escape_hatch():
    p = LTPolicy(mode="UA", hour=3600.0, ua_window=1200.0)
    p.set_targets({("m", "r"): 4}, {("m", "r"): 1000.0}, now=0.0)
    # inside last 20 min, at target, observed >= 5x forecast, util high
    acts = p.on_tick([view(0.9, inst=4, tps=6000.0)], now=2500.0)
    assert acts and acts[0].delta == 1 and "underestimate" in acts[0].reason
    # overestimate: observed <= 0.5x forecast
    acts = p.on_tick([view(0.5, inst=4, tps=300.0)], now=2600.0)
    assert acts and acts[0].delta == -1
    # outside the window: no escape
    p2 = LTPolicy(mode="UA")
    p2.set_targets({("m", "r"): 4}, {("m", "r"): 1000.0}, now=0.0)
    assert p2.on_tick([view(0.9, inst=4, tps=6000.0)], now=100.0) == []


def test_route_global_threshold_then_least():
    utils = {"a": 0.9, "b": 0.5, "c": 0.1}
    assert route_global(utils, ["a", "b", "c"], 0.7) == "b"
    assert route_global({"a": 0.9, "b": 0.95}, ["a", "b"], 0.7) == "a"
    assert route_global(utils, ["c"], 0.7) == "c"


def test_route_global_empty_utils_falls_back_home():
    # regression: used to raise ValueError on min() over an empty dict
    assert route_global({}, ["home", "b"], 0.7) == "home"
    with pytest.raises(ValueError):
        route_global({}, [], 0.7)


def test_route_global_skips_absent_preferred_regions():
    # preferred regions with no deployed endpoint are skipped, not
    # silently treated as candidates
    utils = {"b": 0.9, "c": 0.2}
    assert route_global(utils, ["missing", "c", "b"], 0.7) == "c"
    # none under threshold: least-utilized among *known* regions
    assert route_global({"b": 0.9, "c": 0.8}, ["missing", "b"], 0.7) == "c"


def test_threshold_router_protocol():
    r = ThresholdRouter(threshold=0.7)
    assert r.route({"a": 0.9, "b": 0.5}, ["a", "b"]) == "b"
    assert r.route({}, ["home"]) == "home"


def test_jsq_and_endpoint_pick():
    assert route_jsq({"i1": 100, "i2": 50, "i3": 50}) == "i2"
    assert pick_endpoint({"e1": 0.4, "e2": 0.2}) == "e2"
