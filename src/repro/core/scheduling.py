"""Instance-level request scheduling policies (§6.5): FCFS / EDF / PF / DPA.

A policy is a pure ordering function over the waiting queue: the instance
admits requests in this order until GPU memory is exhausted (requests are
non-preemptible once batched, §2.3).  Requests expose:

  arrival        absolute arrival time (s)
  tier           "IW-F" | "IW-N" | "NIW"
  ttft_deadline  absolute TTFT deadline (s); NIW uses its batch deadline
  priority       NIW only: 1 (default) or 0 (deadline approaching, §6.2)
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Sequence

from repro.api.registry import register

# NIW requests still at priority 1 always sort behind every priority-0 /
# interactive request (paper: "selected only if there are no priority-0
# requests ahead in the queue").
_NIW_TAIL = 1_000_000_000.0


def _is_bg(r) -> bool:
    return r.tier == "NIW" and getattr(r, "priority", 1) == 1


def order_fcfs(reqs: Sequence, now: float) -> List:
    return sorted(reqs, key=lambda r: (_is_bg(r), r.arrival))


def order_edf(reqs: Sequence, now: float) -> List:
    """Ascending remaining-deadline d_r; expired (d_r < 0) naturally first."""
    return sorted(reqs, key=lambda r: (_is_bg(r), r.ttft_deadline - now,
                                       r.arrival))


def order_pf(reqs: Sequence, now: float) -> List:
    """All IW-F (FCFS) strictly before IW-N; NIW-bg last."""
    rank = {"IW-F": 0, "IW-N": 1, "NIW": 2}
    return sorted(reqs, key=lambda r: (_is_bg(r), rank.get(r.tier, 2),
                                       r.arrival))


def order_dpa(reqs: Sequence, now: float, tau_n: float = 30.0,
              tau_p: float = 5.0) -> List:
    """Deadline-and-Priority-Aware (§6.5).

    Buckets: (1) severely expired (d_r < -τ_n)  — starvation guard;
    (2) urgent IW-F (0 ≤ d_r ≤ τ_p); (3) urgent IW-N; (4) non-urgent IW-F;
    (5) non-urgent IW-N; (6) recently expired (-τ_n ≤ d_r < 0).
    """
    def bucket(r):
        d = r.ttft_deadline - now
        fast = r.tier == "IW-F"
        if d < -tau_n:
            return 1
        if d < 0:
            return 6
        if d <= tau_p:
            return 2 if fast else 3
        return 4 if fast else 5

    return sorted(reqs, key=lambda r: (_is_bg(r), bucket(r), r.arrival))


POLICIES: Dict[str, Callable] = {
    "fcfs": order_fcfs,
    "edf": order_edf,
    "pf": order_pf,
    "dpa": order_dpa,
}


def get_policy(name: str, **kw) -> Callable:
    fn = POLICIES[name]
    if kw:
        return functools.partial(fn, **kw)
    return fn


def order_wsl(reqs: Sequence, now: float,
              weights: Dict[str, float] | None = None) -> List:
    """Weighted-slack-first — beyond-paper: the SLA *continuum* the paper
    names as future work ("can evolve into a continuum from fast to slow,
    high to low priority").

    Each tier (or per-request ``sla_weight``) gets a weight; requests are
    ordered by slack/weight, so a tier twice as important tolerates half
    the slack before overtaking.  With weights {IW-F:inf-ish, IW-N:1}
    this degenerates to PF; with equal weights, to EDF — FCFS/EDF/PF are
    special cases of the continuum.
    """
    w = weights or {"IW-F": 8.0, "IW-N": 2.0, "NIW": 1.0}

    def key(r):
        slack = r.ttft_deadline - now
        wt = getattr(r, "sla_weight", None) or w.get(r.tier, 1.0)  # reprolint: disable=R3 -- optional per-request extension attr; not added to the __slots__ Request (memory at 10M-request scale)
        return (_is_bg(r), slack / wt, r.arrival)

    return sorted(reqs, key=key)


POLICIES["wsl"] = order_wsl


# Every ordering function doubles as a registry-resolvable Scheduler:
# resolve("scheduler", "dpa") or resolve("scheduler",
# PolicySpec("dpa", {"tau_p": 10.0})) — extra kwargs are bound with
# functools.partial, keeping the (requests, now) call shape.
for _name in POLICIES:
    register("scheduler", _name)(
        lambda ctx, _n=_name, **kw: get_policy(_n, **kw))
